"""Regenerate the §Dry-run and §Roofline tables in EXPERIMENTS.md from
experiments/dryrun/*.json.  Idempotent: content between the marker pairs
is replaced.

  PYTHONPATH=src python scripts/update_experiments.py
"""
import json
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.roofline import (cell_roofline, load_dryrun_records,
                                     roofline_table)

ROOT = Path(__file__).resolve().parents[1]
MD = ROOT / "EXPERIMENTS.md"


def dryrun_summary(records):
    """Matrix status table for both meshes + headline numbers."""
    base = [r for r in records
            if r.get("mesh") in ("single", "multi")]
    n_ok = sum(r["status"] == "ok" for r in base)
    n_skip = sum(r["status"] == "skip" for r in base)
    n_err = sum(r["status"] == "error" for r in base)
    lines = [f"Matrix state: **{n_ok} compiled**, {n_skip} documented "
             f"skips, {n_err} errors (out of 80 nominal cells; see "
             f"experiments/dryrun/*.json).", ""]
    lines.append("| arch | shape | single-pod (256) | multi-pod (512) | "
                 "per-device collective MB (single) |")
    lines.append("|---|---|---|---|---|")
    by = {}
    for r in base:
        by[(r["arch"], r["shape"], r["mesh"])] = r

    archs = sorted({r["arch"] for r in base})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    def cell(a, s, m):
        r = by.get((a, s, m))
        if r is None:
            return "…"
        if r["status"] == "skip":
            return "skip"
        if r["status"] == "error":
            return "ERR"
        return f"ok ({r['compile_s']:.0f}s)"

    for a in archs:
        for s in shapes:
            r1 = by.get((a, s, "single"))
            coll = ""
            if r1 and r1.get("status") == "ok":
                c = r1.get("collective_bytes_tpu",
                           r1.get("collective_bytes", {}))
                coll = f"{sum(c.values())/1e6:.0f}"
            lines.append(f"| {a} | {s} | {cell(a, s, 'single')} | "
                         f"{cell(a, s, 'multi')} | {coll} |")
    return "\n".join(lines)


def replace_between(text, start, end, new):
    pat = re.compile(re.escape(start) + r".*?" + re.escape(end), re.S)
    assert pat.search(text), f"markers not found: {start}"
    return pat.sub(start + "\n" + new + "\n" + end, text)


def main():
    records = load_dryrun_records()
    text = MD.read_text()
    text = replace_between(
        text, "<!-- DRYRUN-TABLE-START -->", "<!-- DRYRUN-TABLE-END -->",
        dryrun_summary(records))
    text = replace_between(
        text, "<!-- ROOFLINE-TABLE-START -->",
        "<!-- ROOFLINE-TABLE-END -->",
        roofline_table([r for r in records
                        if r.get("mesh") == "single"], mesh="single"))
    MD.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
